"""Paged KV cache: block tables, the device-resident page allocator, the
paged Pallas decode kernel, and the acceptance invariant — paged decode emits
bit-identical token streams to the slab engine under a fixed seed.

Also holds the regression tests for the bugfixes that ride with paging:
mid-block decode overshoot past ``max_len`` (positions freeze, no writes past
the cache) and page-capacity-aware admission.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_paged_pallas
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    PrefillEngine,
    SamplingParams,
)
from repro.serving import kvcache

PAGE = 16


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def hybrid_setup():
    """jamba: mamba + attn — mamba state must stay per-slot while attn pages."""
    cfg = reduced(ARCHS["jamba-1.5-large-398b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=6, lo=5, hi=40):
    rng = np.random.default_rng(seed)
    return [
        GenRequest(i, rng.integers(0, cfg.vocab_size, size=int(rng.integers(lo, hi))),
                   max_new_tokens=max_new)
        for i in range(n)
    ]


def _server(params, cfg, *, paged, max_slots=3, max_len=128, n_pages=None,
            decode_block=8, temperature=0.0, seed=0):
    sp = SamplingParams(temperature=temperature)
    return DisaggregatedServer(
        [PrefillEngine(params, cfg, sp)],
        [DecodeEngine(params, cfg, max_slots=max_slots, max_len=max_len,
                      sampling=sp, decode_block=decode_block, paged=paged,
                      page_size=PAGE, n_pages=n_pages, seed=seed)],
        seed=seed,
    )


# ---------------------------------------------------------------------------
# Paged Pallas kernel vs pure-JAX reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,d", [(3, 4, 2, 16), (2, 8, 8, 32)])
def test_paged_decode_kernel_matches_ref(dtype, B, H, KV, d):
    rng = np.random.default_rng(0)
    P, ps, n_pg = 11, PAGE, 6
    q = jnp.asarray(rng.normal(size=(B, H, d)), dtype)
    kp = jnp.asarray(rng.normal(size=(P, ps, KV, d)), dtype)
    vp = jnp.asarray(rng.normal(size=(P, ps, KV, d)), dtype)
    bt = jnp.asarray(rng.integers(0, P, size=(B, n_pg)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, n_pg * ps, size=(B,)), jnp.int32)
    out = decode_attention_paged_pallas(q, kp, vp, bt, lengths, interpret=True)
    want = ref.decode_attention_paged_ref(q, kp, vp, bt, lengths)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_paged_kernel_ignores_pages_past_length():
    """Entries past ``lengths`` may point anywhere (trash page): masked out."""
    rng = np.random.default_rng(1)
    B, H, KV, d, P, n_pg = 2, 4, 2, 16, 9, 4
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, PAGE, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, PAGE, KV, d)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, size=(B, n_pg)), jnp.int32)
    lengths = jnp.array([PAGE + 3, 2 * PAGE], jnp.int32)
    out1 = decode_attention_paged_pallas(q, kp, vp, bt, lengths, interpret=True)
    # rewire every table entry past the valid prefix to a different page
    bt2 = bt.at[:, 2:].set((bt[:, 2:] + 1) % P)
    out2 = decode_attention_paged_pallas(q, kp, vp, bt2, lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_paged_kernel_max_length_bound():
    rng = np.random.default_rng(2)
    B, H, KV, d, P, n_pg = 2, 4, 2, 16, 9, 8
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, PAGE, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, PAGE, KV, d)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, size=(B, n_pg)), jnp.int32)
    lengths = jnp.array([20, 40], jnp.int32)
    full = decode_attention_paged_pallas(q, kp, vp, bt, lengths, interpret=True)
    bounded = decode_attention_paged_pallas(
        q, kp, vp, bt, lengths, max_length=40, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(full), np.asarray(bounded))


# ---------------------------------------------------------------------------
# Model-level paged decode (decode_step(block_tables=...)) == slab decode.
# This is the XLA twin of the Pallas paged kernel and the wiring the TPU
# backend uses to run decode straight off the pools (no gathered view); the
# engine's per-block view path must stay bit-identical to it.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fixture", ["setup", "hybrid_setup"])
def test_decode_step_block_tables_matches_slab(fixture, request):
    cfg, params = request.getfixturevalue(fixture)
    max_slots, max_len = 3, 64
    n_pages = max_slots * max_len // PAGE
    st = kvcache.init_paged_decode_state(
        cfg, max_slots, max_len, PAGE, n_pages, jax.random.PRNGKey(1)
    )
    slab_caches = M.zeros_cache(cfg, max_slots, max_len)
    toks = jnp.arange(37, dtype=jnp.int32)[None]
    _, single, _ = M.prefill(params, toks, cfg)
    st = kvcache.paged_admit(st, single, jnp.int32(1), jnp.int32(5), jnp.int32(37),
                             cfg, page_size=PAGE)
    slab_caches = kvcache.insert_request(slab_caches, single, 1, cfg)
    tok = jnp.array([0, 5, 0], jnp.int32)
    pos = jnp.array([0, 37, 0], jnp.int32)
    lg_s, slab_caches = M.decode_step(params, tok, slab_caches, pos, cfg)
    lg_p, paged_caches = M.decode_step(params, tok, st.caches, pos, cfg,
                                       block_tables=st.block_tables)
    np.testing.assert_array_equal(np.asarray(lg_s[1]), np.asarray(lg_p[1]))
    # the paged write landed the same K/V at position 37 as the slab write
    back = kvcache.paged_extract_request(
        st._replace(caches=paged_caches), 1, 38, cfg, page_size=PAGE
    )
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer != "attn":
            continue
        for w, g in zip(jax.tree.leaves(slab_caches[i]), jax.tree.leaves(back[i]), strict=True):
            np.testing.assert_array_equal(
                np.asarray(w[:, 1:2, :38], np.float32), np.asarray(g, np.float32)
            )


# ---------------------------------------------------------------------------
# Acceptance: paged engine == slab engine, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_paged_matches_slab_streams(setup, temperature):
    """The tentpole invariant: paged and slab decode produce bit-identical
    token streams under a fixed seed (greedy AND sampled)."""
    cfg, params = setup
    outs = []
    for paged in (False, True):
        srv = _server(params, cfg, paged=paged, temperature=temperature)
        for r in _requests(cfg, 6, seed=1):
            srv.submit(r)
        outs.append(srv.run())
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_paged_matches_slab_hybrid(hybrid_setup):
    """Hybrid mamba/attn: per-slot SSM state + paged attention pools."""
    cfg, params = hybrid_setup
    outs = []
    for paged in (False, True):
        srv = _server(params, cfg, paged=paged)
        for r in _requests(cfg, 5, seed=2, max_new=4):
            srv.submit(r)
        outs.append(srv.run())
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Allocator invariants
# ---------------------------------------------------------------------------


def test_allocator_drains_clean(setup):
    """After every request completes: all pages free, tables trash-mapped,
    reservations zero."""
    cfg, params = setup
    srv = _server(params, cfg, paged=True)
    for r in _requests(cfg, 7, seed=3, max_new=5):
        srv.submit(r)
    srv.run()
    eng = srv.decodes[0]
    assert bool(jnp.all(eng.state.page_refs == 0))
    assert bool(jnp.all(eng.state.block_tables == eng.n_pages))
    assert eng._reserved == [0] * eng.max_slots
    assert not bool(jnp.any(eng.state.active))


def test_pages_bounded_by_reservation_mid_flight(setup):
    """Physically allocated pages never exceed the host-side reservation."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    eng = DecodeEngine(params, cfg, max_slots=3, max_len=128, sampling=sp,
                       decode_block=4, paged=True, page_size=PAGE)
    key = jax.random.PRNGKey(0)
    for r in _requests(cfg, 3, seed=4, max_new=12):
        key, k = jax.random.split(key)
        tok, kv, tl = pre.prefill(r, k)
        assert eng.admit(r, kv, tok, tl) is not None
    while eng.requests:
        eng.step_block()
        used = int(jnp.sum(eng.state.page_refs > 0))
        assert used <= sum(eng._reserved)
        assert used <= eng.n_pages


def test_admission_waits_for_pages(setup):
    """A tiny pool admits fewer concurrent requests than there are slots —
    pages, not slots, are the binding limit — yet continuous batching still
    completes everything."""
    cfg, params = setup
    # every request reserves 2-3 pages (prompt 20-38, max_new=4, block
    # margin) so a 3-page pool serializes them despite 4 free slots
    srv = _server(params, cfg, paged=True, max_slots=4, n_pages=3, decode_block=4)
    for r in _requests(cfg, 5, seed=5, max_new=4, lo=20, hi=39):
        srv.submit(r)
    out = srv.run()
    assert len(out) == 5
    assert all(len(v) == 4 for v in out.values())
    assert srv.peak_active == 1


def test_oversized_page_demand_rejected(setup):
    """A request that could never fit the pool is rejected at submit()."""
    cfg, params = setup
    srv = _server(params, cfg, paged=True, max_slots=2, n_pages=2)
    with pytest.raises(ValueError, match="capacity"):
        srv.submit(GenRequest(0, np.arange(60) % cfg.vocab_size, max_new_tokens=8))


# ---------------------------------------------------------------------------
# Decode-overshoot bugfix: a request ending exactly at max_len, mid-block
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True])
def test_overshoot_at_max_len_frozen(setup, paged):
    """A slot finishing exactly at ``max_len`` inside a decode_block > 1 block
    must not advance positions past the cache or corrupt other slots."""
    cfg, params = setup
    max_len = 64
    sp = SamplingParams(temperature=0.0)

    def drive(decode_block):
        pre = PrefillEngine(params, cfg, sp)
        eng = DecodeEngine(params, cfg, max_slots=2, max_len=max_len, sampling=sp,
                           decode_block=decode_block, paged=paged, page_size=PAGE)
        rng = np.random.default_rng(6)
        # r0 ends exactly at max_len: true_len + max_new == max_len, with
        # max_new chosen so the finish lands mid-block for decode_block=8
        p0 = rng.integers(0, cfg.vocab_size, size=51)
        r0 = GenRequest(0, p0, max_new_tokens=max_len - len(p0))  # 13 tokens
        r1 = GenRequest(1, rng.integers(0, cfg.vocab_size, size=20), max_new_tokens=30)
        key = jax.random.PRNGKey(0)
        for r in (r0, r1):
            key, k = jax.random.split(key)
            tok, kv, tl = pre.prefill(r, k)
            eng.admit(r, kv, tok, tl)
        steps = 0
        while eng.requests and steps < 100:
            steps += 1
            eng.step_block()
        return eng, {0: list(r0.tokens), 1: list(r1.tokens)}

    eng_f, fused = drive(decode_block=8)
    # positions froze at max_len even though the slot overshot mid-block
    assert int(jnp.max(eng_f.state.positions)) <= max_len
    # the companion request is unaffected by r0's overshoot: identical to a
    # step-at-a-time run where r0's slot is released promptly
    _, stepwise = drive(decode_block=1)
    assert fused == stepwise


# ---------------------------------------------------------------------------
# extract_request round trip (decode -> prefill chip reallocation), paged
# ---------------------------------------------------------------------------


def test_paged_extract_reinsert_continuation(setup):
    """insert -> decode a few tokens -> extract -> re-insert into a fresh
    paged engine -> the continuation matches the uninterrupted stream."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    req = _requests(cfg, 1, seed=7, max_new=10)[0]
    key = jax.random.PRNGKey(0)

    def fresh():
        return DecodeEngine(params, cfg, max_slots=2, max_len=128, sampling=sp,
                            decode_block=1, paged=True, page_size=PAGE)

    # uninterrupted reference
    tok, kv, tl = pre.prefill(req, key)
    eng = fresh()
    eng.admit(req, kv, tok, tl)
    while eng.requests:
        eng.step_block()
    full = list(req.tokens)

    # interrupted: decode 4 tokens, extract, re-insert elsewhere, continue
    req2 = _requests(cfg, 1, seed=7, max_new=10)[0]
    tok, kv, tl = pre.prefill(req2, key)
    eng_a = fresh()
    slot = eng_a.admit(req2, kv, tok, tl)
    for _ in range(4):
        eng_a.step_block()
    n_dec = len(req2.tokens) - 1  # tokens after the prefill token
    length = tl + n_dec
    assert eng_a.slots.lengths[slot] == length
    pack = kvcache.paged_extract_request(eng_a.state, slot, length, cfg,
                                         page_size=PAGE)
    cont = GenRequest(99, req2.prompt, max_new_tokens=10 - n_dec)
    eng_b = fresh()
    eng_b.admit(cont, pack, req2.tokens[-1], length)
    while eng_b.requests:
        eng_b.step_block()
    assert req2.tokens[:-1] + cont.tokens == full
