"""FP004 bad (quant): a scale-leaf hold increment with no release path.

The int8 KV path mirrors per-page scale holds in ``_scale_refs``; like
``_href`` / ``_chunk_holds``, every increment must pair with a decrement
reachable from the ``_forget`` funnel or quantized pages leak their scales.
"""


class QuantPool:
    def __init__(self):
        self._scale_refs = {}

    def admit_quant(self, p):
        self._scale_refs[p] = self._scale_refs.get(p, 0) + 1
