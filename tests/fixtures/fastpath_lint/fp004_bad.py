"""FP004 bad: a hold increment with no release path through _forget."""


class Pool:
    def __init__(self):
        self._href = {}

    def admit(self, p):
        self._href[p] = self._href.get(p, 0) + 1
