"""FP005 good: all randomness flows from a seeded generator."""
import numpy as np


def make_rng(seed):
    return np.random.default_rng(seed)


def jitter(rng):
    return rng.random()
