"""FP001 bad: np.asarray inside a jitted body."""
import jax
import numpy as np


def body(x):
    return np.asarray(x).sum()


step = jax.jit(body)
