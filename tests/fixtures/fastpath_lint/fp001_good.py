"""FP001 good: device-side jnp.asarray, plus one audited allow."""
import jax
import jax.numpy as jnp
import numpy as np


def body(x):
    return jnp.asarray(x).sum()


def step_done(x):
    return np.asarray(x)  # fastpath: allow[FP001] lifecycle-cadence readback


step = jax.jit(body)
final = jax.jit(step_done)
