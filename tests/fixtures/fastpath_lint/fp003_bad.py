"""FP003 bad: a len()-derived scalar keys the jit cache directly."""


class Prefill:
    def __init__(self):
        self._fns = {}

    def get(self, prompt):
        S = len(prompt)
        key = (S, 1)
        if key not in self._fns:
            self._fns[key] = object()
        return self._fns[key]
