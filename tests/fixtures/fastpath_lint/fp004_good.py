"""FP004 good: the increment pairs with a decrement reachable from _forget."""


class Pool:
    def __init__(self):
        self._href = {}

    def admit(self, p):
        self._href[p] = self._href.get(p, 0) + 1

    def _release(self, p):
        self._href[p] -= 1

    def _forget(self, p):
        self._release(p)
