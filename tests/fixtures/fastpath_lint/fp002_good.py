"""FP002 good: the donate-and-rebind idiom."""
import jax

step = jax.jit(lambda s: s, donate_argnums=(0,))


def caller(state):
    state = step(state)
    return state.tokens
