"""FP005 bad: unseeded np.random in faults code."""
import numpy as np


def jitter():
    return np.random.random()
