"""FP003 good: the key passes through the bucketing function."""


def _bucket(n):
    return max(16, 1 << (n - 1).bit_length())


class Prefill:
    def __init__(self):
        self._fns = {}

    def get(self, prompt):
        S = _bucket(len(prompt))
        key = (S, 1)
        if key not in self._fns:
            self._fns[key] = object()
        return self._fns[key]
