"""An allow suppresses exactly the finding on its own line, not others."""
import jax
import numpy as np


def body(x):
    a = np.asarray(x)  # fastpath: allow[FP001] the audited one
    b = np.asarray(x)
    return a + b


step = jax.jit(body)
