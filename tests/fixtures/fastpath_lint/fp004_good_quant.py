"""FP004 good (quant): the scale-leaf hold pairs with a funnel release."""


class QuantPool:
    def __init__(self):
        self._scale_refs = {}

    def admit_quant(self, p):
        self._scale_refs[p] = self._scale_refs.get(p, 0) + 1

    def _release_scales(self, p):
        self._scale_refs[p] -= 1

    def _forget(self, p):
        self._release_scales(p)
