"""FP002 bad: state read after being passed through a donated position."""
import jax

step = jax.jit(lambda s: s, donate_argnums=(0,))


def caller(state):
    out = step(state)
    return out, state.tokens
