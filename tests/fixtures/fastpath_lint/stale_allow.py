"""An allow-comment on a clean line must itself be an error."""
import numpy as np


def pure_host(x):
    return np.sum(x)  # fastpath: allow[FP001] nothing to suppress here
