"""Layer-1 analyzer tests: exact finding locations per fixture, allow-comment
semantics (suppress exactly one finding; stale allows are errors), and the
CLI exit-code contract (non-zero on violations, zero on src/repro at HEAD)."""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.lint import lint_files, lint_paths

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "fastpath_lint"
CLI = REPO / "tools" / "fastpath_lint.py"


def _lint(name):
    return lint_paths([str(FIXTURES / name)])


def _sites(report):
    return [(Path(f.path).name, f.line, f.rule) for f in report.findings]


# ---------------------------------------------------------------- bad corpus

BAD_EXPECT = {
    "fp001_bad.py": [("fp001_bad.py", 7, "FP001")],
    "fp002_bad.py": [("fp002_bad.py", 9, "FP002")],
    "fp003_bad.py": [("fp003_bad.py", 12, "FP003")],
    "fp004_bad.py": [("fp004_bad.py", 9, "FP004")],
    "fp004_bad_quant.py": [("fp004_bad_quant.py", 14, "FP004")],
    "fp005_bad_faults.py": [("fp005_bad_faults.py", 6, "FP005")],
}


@pytest.mark.parametrize("name", sorted(BAD_EXPECT))
def test_bad_fixture_exact_location(name):
    report = _lint(name)
    assert _sites(report) == BAD_EXPECT[name]


@pytest.mark.parametrize("name", sorted(BAD_EXPECT))
def test_cli_exits_nonzero_on_violation(name):
    proc = subprocess.run(
        [sys.executable, str(CLI), str(FIXTURES / name)],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr
    rule = f"FP{name[2:5]}"
    assert rule in proc.stdout


# --------------------------------------------------------------- good corpus


@pytest.mark.parametrize(
    "name",
    [
        "fp001_good.py",
        "fp002_good.py",
        "fp003_good.py",
        "fp004_good.py",
        "fp004_good_quant.py",
        "fp005_good_faults.py",
    ],
)
def test_good_fixture_clean(name):
    report = _lint(name)
    assert not report.failed, _sites(report) + [str(e) for e in report.errors]


def test_good_fp001_allow_is_counted():
    report = _lint("fp001_good.py")
    assert len(report.allowed) == 1
    allow, finding = report.allowed[0]
    assert allow.rule == finding.rule == "FP001"


# ------------------------------------------------------------ allow semantics


def test_allow_suppresses_exactly_one_finding():
    report = _lint("suppress_one.py")
    assert len(report.allowed) == 1
    assert _sites(report) == [("suppress_one.py", 8, "FP001")]


def test_stale_allow_is_an_error():
    report = _lint("stale_allow.py")
    assert not report.findings
    assert len(report.errors) == 1
    assert report.errors[0].rule == "FP000"
    assert "stale" in report.errors[0].message
    assert report.failed


def test_allow_without_reason_is_an_error():
    src = (
        "import jax\nimport numpy as np\n\n\n"
        "def body(x):\n"
        "    return np.asarray(x)  # fastpath: allow[FP001]\n\n\n"
        "step = jax.jit(body)\n"
    )
    report = lint_files({"reasonless.py": src})
    assert any("no reason" in e.message for e in report.errors)


def test_allow_on_own_line_targets_next_line():
    src = (
        "import jax\nimport numpy as np\n\n\n"
        "def body(x):\n"
        "    # fastpath: allow[FP001] audited readback\n"
        "    return np.asarray(x)\n\n\n"
        "step = jax.jit(body)\n"
    )
    report = lint_files({"ownline.py": src})
    assert not report.failed
    assert len(report.allowed) == 1


def test_docstring_mentioning_allow_syntax_is_not_an_allow():
    src = '"""Docs: use `# fastpath: allow[FP001] reason` to annotate."""\n'
    report = lint_files({"doconly.py": src})
    assert not report.failed
    assert not report.allowed


# ------------------------------------------------------------- HEAD is clean


def test_src_repro_clean_at_head():
    report = lint_paths([str(REPO / "src" / "repro")])
    assert not report.failed, [str(f) for f in report.findings + report.errors]
    # the audited lifecycle syncs stay visible as counted allows
    assert len(report.allowed) >= 15


def test_cli_exits_zero_on_head():
    proc = subprocess.run(
        [sys.executable, str(CLI)],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_select_filters_rules():
    report = lint_paths([str(FIXTURES / "fp001_bad.py")], select={"FP003"})
    assert not report.findings
