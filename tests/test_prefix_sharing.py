"""Refcounted prefix sharing + copy-on-write for the paged KV cache.

The acceptance invariant: a server with ``prefix_cache=True`` emits token
streams BIT-IDENTICAL to the unshared paged engine (greedy and sampled,
attention-only / hybrid / MLA) while reserving strictly fewer new KV pages
for shared-prefix workloads.  Plus the allocator invariants that make it
safe: release is decrement-only (a page is reclaimed only at refcount 0),
the prefix index holds a +1 cache ref per registered page, LRU eviction
frees cache-only pages under admission pressure, and ``fork()`` branches
diverge through copy-on-write without corrupting the shared prefix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_paged_pallas
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    PrefillEngine,
    SamplingParams,
)
from repro.serving.prefix_cache import PrefixIndex, chunk_hashes

PAGE = 16


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def mla_setup():
    cfg = reduced(ARCHS["minicpm3-4b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def hybrid_setup():
    """jamba: SSM state is a whole-prompt function — sharing must fall back
    to full recompute + page mapping (capacity win, no compute win)."""
    cfg = reduced(ARCHS["jamba-1.5-large-398b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _shared_requests(cfg, n, base=0, prefix_len=32, lo=4, hi=16, max_new=5, seed=0):
    """n requests sharing a ``prefix_len``-token system prompt + unique tails."""
    rng = np.random.default_rng(seed)
    common = rng.integers(0, cfg.vocab_size, size=prefix_len)
    tails = np.random.default_rng(seed + base + 1)
    return [
        GenRequest(
            base + i,
            np.concatenate(
                [common, tails.integers(0, cfg.vocab_size, size=int(tails.integers(lo, hi)))]
            ),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _server(params, cfg, *, prefix, max_slots=4, max_len=128, n_pages=None,
            decode_block=8, temperature=0.0, seed=0, max_prefill_batch=8):
    sp = SamplingParams(temperature=temperature)
    return DisaggregatedServer(
        [PrefillEngine(params, cfg, sp)],
        [DecodeEngine(params, cfg, max_slots=max_slots, max_len=max_len,
                      sampling=sp, decode_block=decode_block, paged=True,
                      page_size=PAGE, n_pages=n_pages, seed=seed,
                      prefix_cache=prefix)],
        seed=seed, max_prefill_batch=max_prefill_batch,
    )


def _run_waves(srv, cfg, waves=2, n=4, **kw):
    """Two submission waves: wave 1 populates the index (admit-time page
    mapping), wave 2 exercises the tail-only prefill path."""
    out = {}
    for w in range(waves):
        for r in _shared_requests(cfg, n, base=w * 100, **kw):
            srv.submit(r)
        out.update(srv.run())
    return out


# ---------------------------------------------------------------------------
# Acceptance: shared streams == unshared streams, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_shared_streams_match_unshared(setup, temperature):
    cfg, params = setup
    outs = []
    for prefix in (False, True):
        # max_prefill_batch=1 keeps the prefill PRNG-key sequence identical
        # between the two schedules for the sampled case
        srv = _server(params, cfg, prefix=prefix, temperature=temperature,
                      max_prefill_batch=1 if temperature else 8)
        outs.append(_run_waves(srv, cfg))
        if prefix:
            eng = srv.decodes[0]
            assert eng.stats["shared_pages"] > 0, "no sharing happened"
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_shared_streams_match_unshared_mla(mla_setup):
    cfg, params = mla_setup
    outs = []
    for prefix in (False, True):
        srv = _server(params, cfg, prefix=prefix)
        outs.append(_run_waves(srv, cfg))
        if prefix:
            eng = srv.decodes[0]
            assert eng.stats["shared_pages"] > 0
            # MLA is attention-only: wave 2 must use tail-only prefill
            assert any(len(k) == 3 for k in srv.prefills[0]._fns)
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_shared_streams_match_unshared_hybrid(hybrid_setup):
    cfg, params = hybrid_setup
    outs = []
    for prefix in (False, True):
        srv = _server(params, cfg, prefix=prefix)
        outs.append(_run_waves(srv, cfg))
        if prefix:
            eng = srv.decodes[0]
            assert not eng._tail_ok
            assert eng.stats["shared_pages"] > 0
            # hybrid never tail-prefills (SSM state needs the whole prompt)
            assert not any(len(k) == 3 for k in srv.prefills[0]._fns)
    assert outs[0] == outs[1]


def test_tail_prefill_used_and_streams_match(setup):
    """Wave 2 requests (prefix already registered) go through the tail-only
    prefill path — distinct (S, B, Lp) jit keys — and still match bitwise."""
    cfg, params = setup
    srv_ref = _server(params, cfg, prefix=False)
    out_ref = _run_waves(srv_ref, cfg)
    srv = _server(params, cfg, prefix=True)
    out = _run_waves(srv, cfg)
    assert out == out_ref
    tail_keys = [k for k in srv.prefills[0]._fns if len(k) == 3]
    assert tail_keys, "tail-only prefill never compiled"


# ---------------------------------------------------------------------------
# Accounting: reservations count only NEW pages; refcounts mirror sharing
# ---------------------------------------------------------------------------


def test_admit_reserves_only_new_pages(setup):
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    eng = DecodeEngine(params, cfg, max_slots=4, max_len=128, sampling=sp,
                       decode_block=4, paged=True, page_size=PAGE,
                       prefix_cache=True)
    a, b = _shared_requests(cfg, 2, prefix_len=32, lo=8, hi=9, max_new=4)
    key = jax.random.PRNGKey(0)
    tok, kv, tl = pre.prefill(a, key)
    assert eng.admit(a, kv, tok, tl) is not None
    full_need = eng._pages_needed(tl, a.max_new_tokens)
    assert eng.admit_new_pages[a.rid] == full_need  # first request: all new
    assert eng.admit_shared_pages[a.rid] == 0
    # two full prompt chunks registered, each holding a +1 cache ref
    assert len(eng.prefix) == 2
    shared_pages = eng.prefix.pages()
    refs = np.asarray(eng.state.page_refs)
    assert all(refs[p] == 2 for p in shared_pages)  # slot + cache

    tok, kv, tl = pre.prefill(b, key)
    m = eng.match_prefix(b.prompt, rid=b.rid)
    assert m.n_shared == 2
    assert not m.tail  # a match never claims a tail pack; the scheduler does
    assert eng.admit(b, kv, tok, tl, prefix=m) is not None
    assert eng.admit_shared_pages[b.rid] == 2
    assert eng.admit_new_pages[b.rid] == eng._pages_needed(tl, b.max_new_tokens) - 2
    assert eng._reserved[1] == eng.admit_new_pages[b.rid]
    refs = np.asarray(eng.state.page_refs)
    assert all(refs[p] == 3 for p in shared_pages)  # 2 slots + cache

    # the direct-API pattern above hands admit a FULL-prompt pack with a
    # match: decode must stay bit-identical to an unshared engine
    # (regression: a tail=True match here would mis-scatter the pack)
    while eng.requests:
        eng.step_block()
    ref_eng = DecodeEngine(params, cfg, max_slots=4, max_len=128, sampling=sp,
                           decode_block=4, paged=True, page_size=PAGE)
    a2, b2 = _shared_requests(cfg, 2, prefix_len=32, lo=8, hi=9, max_new=4)
    for r in (a2, b2):
        tok, kv, tl = pre.prefill(r, key)
        ref_eng.admit(r, kv, tok, tl)
    while ref_eng.requests:
        ref_eng.step_block()
    assert a.tokens == a2.tokens
    assert b.tokens == b2.tokens


def test_release_is_decrement_only(setup):
    """The paged_release fix: freeing one sharer decrements, never zeroes; a
    page is reclaimed (allocatable) only when the LAST holder lets go."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    eng = DecodeEngine(params, cfg, max_slots=4, max_len=128, sampling=sp,
                       decode_block=4, paged=True, page_size=PAGE,
                       prefix_cache=True)
    a = _shared_requests(cfg, 1, prefix_len=32, lo=8, hi=9, max_new=2)[0]
    b = _shared_requests(cfg, 1, base=50, prefix_len=32, lo=8, hi=9, max_new=24)[0]
    key = jax.random.PRNGKey(0)
    tok, kv, tl = pre.prefill(a, key)
    eng.admit(a, kv, tok, tl)
    shared_pages = eng.prefix.pages()
    tok, kv, tl = pre.prefill(b, key)
    eng.admit(b, kv, tok, tl, prefix=eng.match_prefix(b.prompt))
    # run until a (max_new=2) finishes; b keeps decoding
    while a.rid in eng.requests:
        eng.step_block()
    refs = np.asarray(eng.state.page_refs)
    # a's release decremented the shared pages but b + cache still hold them
    assert all(refs[p] == 2 for p in shared_pages)
    assert bool(jnp.all(eng.state.page_refs >= 0))
    while eng.requests:
        eng.step_block()
    refs = np.asarray(eng.state.page_refs)
    assert all(refs[p] == 1 for p in shared_pages)  # cache-only now
    # everything not cache-held drained to refs == 0
    others = [p for p in range(eng.n_pages) if p not in shared_pages]
    assert all(refs[p] == 0 for p in others)


def test_cached_pages_not_reallocated(setup):
    """Reclaim-only-at-zero, from the allocator side: pages held by the
    prefix cache (refs > 0) are never handed to a new request's fresh
    allocation."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    eng = DecodeEngine(params, cfg, max_slots=4, max_len=128, sampling=sp,
                       decode_block=4, paged=True, page_size=PAGE,
                       prefix_cache=True)
    a = _shared_requests(cfg, 1, prefix_len=32, lo=8, hi=9, max_new=2)[0]
    key = jax.random.PRNGKey(0)
    tok, kv, tl = pre.prefill(a, key)
    eng.admit(a, kv, tok, tl)
    while eng.requests:
        eng.step_block()
    cached = set(eng.prefix.pages())
    # a fresh UNRELATED request must not receive the cached pages
    c = GenRequest(7, np.random.default_rng(9).integers(0, cfg.vocab_size, size=40),
                   max_new_tokens=4)
    tok, kv, tl = pre.prefill(c, key)
    slot = eng.admit(c, kv, tok, tl)
    row = set(eng._slot_pages[slot])
    assert not (row & cached)


def test_lru_eviction_under_pressure(setup):
    """A tiny pool: cache-only pages are LRU-evicted so admission never
    starves, and the index shrinks accordingly."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    eng = DecodeEngine(params, cfg, max_slots=2, max_len=128, sampling=sp,
                       decode_block=2, paged=True, page_size=PAGE, n_pages=8,
                       prefix_cache=True)
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(3)
    for i in range(4):
        # distinct 32-token prompts: each admit registers 2 chunks
        r = GenRequest(i, rng.integers(0, cfg.vocab_size, size=34), max_new_tokens=2)
        tok, kv, tl = pre.prefill(r, key)
        assert eng.admit(r, kv, tok, tl) is not None, f"admission starved at {i}"
        while eng.requests:
            eng.step_block()
    # pool is 8 pages; 4 requests x 2 cached chunks would need 8 cache-only
    # pages + working pages -> eviction must have run
    assert len(eng.prefix) < 8
    refs = np.asarray(eng.state.page_refs)
    assert bool(jnp.all(eng.state.page_refs >= 0))
    assert int((refs > 0).sum()) == len(eng.prefix)


# ---------------------------------------------------------------------------
# Copy-on-write: fork() branches diverge without corrupting the shared pages
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prompt_len", [37, 32])  # mid-page and page-aligned
def test_fork_cow_divergence(setup, prompt_len):
    """Fork a live request mid-decode with a different branch token: both
    branches continue past the shared page; COW must give the writer(s) a
    private copy so the original's stream stays bit-identical to a no-fork
    run."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    prompt = np.random.default_rng(5).integers(0, cfg.vocab_size, size=prompt_len)
    key = jax.random.PRNGKey(0)

    def fresh():
        return DecodeEngine(params, cfg, max_slots=3, max_len=128, sampling=sp,
                            decode_block=4, paged=True, page_size=PAGE, seed=0)

    r0 = GenRequest(0, prompt, max_new_tokens=12)
    tok, kv, tl = pre.prefill(r0, key)
    eng = fresh()
    eng.admit(r0, kv, tok, tl)
    while eng.requests:
        eng.step_block()
    ref_stream = list(r0.tokens)

    r1 = GenRequest(1, prompt, max_new_tokens=12)
    tok, kv, tl = pre.prefill(r1, key)
    eng = fresh()
    eng.admit(r1, kv, tok, tl)
    eng.step_block()  # 4 tokens in; fork mid-stream
    alt = int((ref_stream[4] + 1) % cfg.vocab_size)
    r2 = GenRequest(2, prompt, max_new_tokens=12)
    slot = eng.fork(r2, src_rid=1, token=alt)
    assert slot is not None
    # the fork shares every mapped page: refs == 2 on the prompt pages
    refs = np.asarray(eng.state.page_refs)
    n_mapped = -(-min(eng.slots.lengths[0], 128) // PAGE)
    src_row = np.asarray(eng.state.block_tables[0])[:n_mapped]
    assert all(refs[p] == 2 for p in src_row)
    while eng.requests:
        eng.step_block()
    # original branch: bit-identical to the no-fork reference (COW protected
    # the shared tail page from the other branch's writes)
    assert r1.tokens == ref_stream
    # fork branch: same prefix, diverges exactly at the overridden token
    assert r2.tokens[:4] == ref_stream[:4]
    assert r2.tokens[4] == alt
    assert r2.tokens != ref_stream
    assert len(r2.tokens) == 12
    # both branches ended with private pages; nothing leaked or went negative
    assert bool(jnp.all(eng.state.page_refs == 0))


def test_fork_capacity_reserved(setup):
    """Fork reserves growth + COW margin; an exhausted pool refuses the fork
    instead of silently corrupting pages."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    eng = DecodeEngine(params, cfg, max_slots=3, max_len=128, sampling=sp,
                       decode_block=4, paged=True, page_size=PAGE, n_pages=8)
    r0 = GenRequest(0, np.random.default_rng(6).integers(0, cfg.vocab_size, size=40),
                    max_new_tokens=60)
    key = jax.random.PRNGKey(0)
    tok, kv, tl = pre.prefill(r0, key)
    assert eng.admit(r0, kv, tok, tl) is not None  # needs 7 of 8 pages
    r1 = GenRequest(1, r0.prompt, max_new_tokens=60)
    assert eng.fork(r1, src_rid=0) is None  # growth + COW margin exceed the pool
    assert eng.slots.n_active == 1  # no half-forked slot left behind


# ---------------------------------------------------------------------------
# Kernel/ref paths honor shared (aliased) and remapped block tables
# ---------------------------------------------------------------------------


def test_paged_kernel_honors_shared_tables():
    """Two rows aliasing the same physical pages (shared prefix) must read
    the same K/V as two rows with duplicated private copies — for both the
    Pallas kernel and the pure-JAX reference."""
    rng = np.random.default_rng(4)
    B, H, KV, d, P, n_pg = 2, 4, 2, 16, 9, 4
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, PAGE, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, PAGE, KV, d)), jnp.float32)
    # shared: both rows read pages [0, 1]; private: row 1 reads copies [2, 3]
    kp2 = kp.at[2].set(kp[0]).at[3].set(kp[1])
    vp2 = vp.at[2].set(vp[0]).at[3].set(vp[1])
    bt_shared = jnp.asarray([[0, 1, 4, 4], [0, 1, 5, 5]], jnp.int32)
    bt_priv = jnp.asarray([[0, 1, 4, 4], [2, 3, 5, 5]], jnp.int32)
    lengths = jnp.asarray([2 * PAGE, 2 * PAGE], jnp.int32)
    for fn in (
        lambda *a: decode_attention_paged_pallas(*a, interpret=True),
        ref.decode_attention_paged_ref,
    ):
        shared = fn(q, kp2, vp2, bt_shared, lengths)
        priv = fn(q, kp2, vp2, bt_priv, lengths)
        np.testing.assert_array_equal(np.asarray(shared), np.asarray(priv))


# ---------------------------------------------------------------------------
# Host index unit behavior
# ---------------------------------------------------------------------------


def test_chunk_hashes_are_prefix_complete():
    a = chunk_hashes(np.arange(48), 16)
    b = chunk_hashes(np.concatenate([np.arange(16) + 1, np.arange(16, 48)]), 16)
    assert len(a) == 3
    # same chunk bodies after a different first chunk -> different hashes
    assert a[0] != b[0] and a[1] != b[1] and a[2] != b[2]
    # identical prefix -> identical chain
    assert chunk_hashes(np.arange(40), 16) == a[:2]


def test_prefix_index_lru_and_pins():
    idx = PrefixIndex(16)
    idx.insert(b"a", 0)
    idx.insert(b"b", 1)
    idx.insert(b"c", 2)
    assert idx.match([b"a", b"b", b"x"]) == [0, 1]
    # c is now LRU-oldest; pin it and eviction must skip to nothing else
    idx.pin([2])
    assert idx.evict_one(lambda p: p == 2) is None
    idx.unpin([2])
    assert idx.evict_one(lambda p: p == 2) == 2
    assert len(idx) == 2
