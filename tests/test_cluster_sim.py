"""Cluster simulator + provisioning behaviour (paper §6-7 machinery)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import DECODE_CHIP, H100, PREFILL_CHIP, Parallelism
from repro.core.cluster import (
    SLOS,
    ModelPerf,
    simulate_colocated,
    simulate_disaggregated,
)
from repro.core.provision import Design, PoolSpec, evaluate, max_rate
from repro.core.trace import CODING, CONVERSATION, summarize, synthesize

BLOOM = get_config("bloom-176b")
PAR = Parallelism(tp=8)


@pytest.fixture(scope="module")
def perfs():
    return {
        "h100": ModelPerf(H100, BLOOM, PAR),
        "p": ModelPerf(PREFILL_CHIP, BLOOM, PAR),
        "d": ModelPerf(DECODE_CHIP, BLOOM, PAR),
    }


# ---------------------------------------------------------------------------
# Traces
# ---------------------------------------------------------------------------


def test_trace_statistics():
    reqs = synthesize(CODING, rate_rps=50, duration_s=60, seed=0)
    s = summarize(reqs)
    assert abs(s["median_in"] - 1500) / 1500 < 0.15
    assert abs(s["median_out"] - 13) / 13 < 0.4
    reqs = synthesize(CONVERSATION, rate_rps=50, duration_s=60, seed=0)
    s = summarize(reqs)
    assert abs(s["median_in"] - 1020) / 1020 < 0.15
    assert abs(s["median_out"] - 129) / 129 < 0.3


def test_trace_deterministic():
    a = synthesize(CODING, rate_rps=10, duration_s=10, seed=42)
    b = synthesize(CODING, rate_rps=10, duration_s=10, seed=42)
    assert [(r.t_arrival, r.n_in, r.n_out) for r in a] == [
        (r.t_arrival, r.n_in, r.n_out) for r in b
    ]


# ---------------------------------------------------------------------------
# ModelPerf lookups
# ---------------------------------------------------------------------------


def test_perf_monotonicity(perfs):
    h = perfs["h100"]
    assert h.prefill_time(512) < h.prefill_time(2048) < h.prefill_time(8192)
    assert h.decode_time(1, 1024) < h.decode_time(64, 1024)
    assert h.decode_time(32, 512) < h.decode_time(32, 8192)
    # batching efficiency: 2 fused prefills beat 2 sequential ones
    assert h.prefill_batch_time(2048, 2) < 2 * h.prefill_time(1024)


def test_perf_chip_ordering(perfs):
    """Prefill chip faster at prefill; decode chip ~ H100 at decode."""
    assert perfs["p"].prefill_time(4096) < perfs["h100"].prefill_time(4096)
    d_ratio = perfs["d"].decode_time(64, 2048) / perfs["h100"].decode_time(64, 2048)
    assert d_ratio < 1.15


# ---------------------------------------------------------------------------
# Simulators
# ---------------------------------------------------------------------------


def _mini_trace(rate=6, dur=20, seed=0):
    return synthesize(CONVERSATION, rate_rps=rate, duration_s=dur, seed=seed)


def test_disagg_completes_and_meets_when_overprovisioned(perfs):
    reqs = _mini_trace()
    res = simulate_disaggregated(
        reqs,
        prefill_pool=[perfs["h100"]] * 4,
        decode_pool=[perfs["h100"]] * 4,
        ref_perf=perfs["h100"],
        duration=20,
    )
    assert res.n_completed == res.n_requests
    assert res.meets(SLOS["loose"])
    assert res.percentile("ttft", 90) >= 1.0  # can't beat solo reference


def test_disagg_fails_when_underprovisioned(perfs):
    reqs = synthesize(CONVERSATION, rate_rps=30, duration_s=20, seed=0)
    res = simulate_disaggregated(
        reqs,
        prefill_pool=[perfs["h100"]],
        decode_pool=[perfs["h100"]],
        ref_perf=perfs["h100"],
        duration=20,
    )
    assert not res.meets(SLOS["tight"])


def test_coloc_interference_inflates_tbt(perfs):
    """Sarathi-style mixing must show prefill-decode interference (paper §2.3)."""
    reqs = _mini_trace(rate=8)
    res_co = simulate_colocated(
        reqs, perf=perfs["h100"], n_machines=4, ref_perf=perfs["h100"], duration=20
    )
    res_dis = simulate_disaggregated(
        reqs, prefill_pool=[perfs["h100"]] * 2, decode_pool=[perfs["h100"]] * 2,
        ref_perf=perfs["h100"], duration=20,
    )
    assert res_co.percentile("tbt", 99) > res_dis.percentile("tbt", 99)


def test_spad_cheaper_than_homogeneous(perfs):
    """The paper's headline: same machine counts, SPAD chips cost less."""
    spad = Design(
        "spad", "disagg",
        prefill=[PoolSpec("PrefillChip", perfs["p"], 4)],
        decode=[PoolSpec("DecodeChip", perfs["d"], 4)],
    )
    homo = Design(
        "homo", "disagg",
        prefill=[PoolSpec("H100", perfs["h100"], 4)],
        decode=[PoolSpec("H100", perfs["h100"], 4)],
    )
    assert spad.norm_cost < 0.75 * homo.norm_cost
    reqs = _mini_trace()
    r_spad = evaluate(spad, reqs, perfs["h100"], 20)
    r_homo = evaluate(homo, reqs, perfs["h100"], 20)
    assert r_spad.n_completed == r_spad.n_requests
    # SPAD within SLO whenever homo is (equal machine counts)
    if r_homo.meets(SLOS["normal"]):
        assert r_spad.meets(SLOS["normal"])


def test_max_rate_monotone_in_machines(perfs):
    small = Design(
        "s", "disagg",
        prefill=[PoolSpec("H100", perfs["h100"], 1)],
        decode=[PoolSpec("H100", perfs["h100"], 1)],
    )
    big = Design(
        "b", "disagg",
        prefill=[PoolSpec("H100", perfs["h100"], 3)],
        decode=[PoolSpec("H100", perfs["h100"], 3)],
    )
    r_small = max_rate(small, workload=CONVERSATION, slo=SLOS["normal"],
                       ref_perf=perfs["h100"], duration=15, hi=60)
    r_big = max_rate(big, workload=CONVERSATION, slo=SLOS["normal"],
                     ref_perf=perfs["h100"], duration=15, hi=60)
    assert r_big >= r_small
    assert r_big > 0
