"""Unified batching: decode-maximal rounds that batch page-aligned chunks of
DIFFERENT requests into one prefill dispatch and coalesce chunk work with the
decode step under a per-round token budget.

The acceptance invariant: ``unified_batching=True`` emits token streams
BIT-IDENTICAL to the serial one-chunk-per-round schedule (the committed
regression anchor) — riders change WHEN chunk work runs, never what it
computes.  Plus the budget mechanics: riders join only under budget headroom,
tight budgets defer chunk work to decode-only rounds (bounded by the aging
limit), and the config layer rejects unsatisfiable budgets at construction.
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import DisaggregatedServer, EngineConfig, GenRequest
from repro.serving.autotune import chunk_candidates, tune_chunk_tokens

PAGE = 16


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def hybrid_setup():
    """jamba: per-row conv/SSD carry must survive the batched chunk round."""
    cfg = reduced(ARCHS["jamba-1.5-large-398b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _config(**kw):
    base = dict(
        max_slots=4, max_len=160, decode_block=4, paged=True, page_size=PAGE,
        chunk_tokens=32, max_prefill_batch=4,
    )
    base.update(kw)
    return EngineConfig(**base)


def _mixed_requests(cfg, *, long_rids=(0, 3), n=8, long_len=96, max_new=6,
                    seed=17):
    """Long (chunked) prompts at ``long_rids`` interleaved with shorts."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        ln = long_len if i in long_rids else int(rng.integers(5, 20))
        out.append(GenRequest(i, rng.integers(0, cfg.vocab_size, size=ln),
                              max_new_tokens=max_new))
    return out


def _run(params, cfg, reqs, **cfg_kw):
    srv = DisaggregatedServer.from_config(params, cfg, _config(**cfg_kw))
    for r in reqs:
        srv.submit(r)
    out = srv.run()
    return out, srv


# ---------------------------------------------------------------------------
# Acceptance: unified streams == serial streams, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("temperature", [0.0, 20.0])
def test_unified_matches_serial(setup, temperature):
    cfg, params = setup
    from repro.serving import SamplingParams

    kw = dict(sampling=SamplingParams(temperature=temperature))
    off, _ = _run(params, cfg, _mixed_requests(cfg), **kw)
    on, srv = _run(params, cfg, _mixed_requests(cfg), unified_batching=True, **kw)
    assert on == off
    st = srv.unified_stats
    assert st["rounds"] > 0 and st["chunk_rows"] >= st["rounds"]
    assert st["used_tokens"] <= st["budget_tokens"]


@pytest.mark.slow
def test_riders_batch_multiple_requests(setup):
    """With several chunked prompts in flight the default budget fills idle
    prefill rows with riders: more chunk rows complete than rounds run."""
    cfg, params = setup
    reqs = _mixed_requests(cfg, long_rids=(0, 2, 3), n=6)
    off, _ = _run(params, cfg, reqs)
    on, srv = _run(params, cfg, _mixed_requests(cfg, long_rids=(0, 2, 3), n=6),
                   unified_batching=True)
    assert on == off
    assert srv.unified_stats["chunk_rows"] > srv.unified_stats["rounds"]


@pytest.mark.slow
def test_unified_hybrid_matches_serial(hybrid_setup):
    """Hybrid: each rider row's mamba carry is sliced back out of the batched
    chunk pack; a wrong slice would corrupt the NEXT chunk, not this one."""
    cfg, params = hybrid_setup
    reqs = _mixed_requests(cfg, long_rids=(0, 1), n=5, max_new=4)
    off, _ = _run(params, cfg, reqs)
    on, srv = _run(params, cfg,
                   _mixed_requests(cfg, long_rids=(0, 1), n=5, max_new=4),
                   unified_batching=True)
    assert on == off
    assert srv.unified_stats["chunk_rows"] > 0


# ---------------------------------------------------------------------------
# Budget mechanics
# ---------------------------------------------------------------------------


def test_default_budget_formula(setup):
    cfg, params = setup
    srv = DisaggregatedServer.from_config(params, cfg,
                                          _config(unified_batching=True))
    q = 32
    want = (sum(d.max_slots * d.decode_block for d in srv.decodes)
            + srv.max_prefill_batch * q)
    assert srv.round_token_budget(q) == want
    srv._token_budget = 100
    assert srv.round_token_budget(q) == 100


@pytest.mark.slow
def test_tight_budget_defers_but_completes(setup):
    """A floor budget (one decode block + one chunk) makes saturated rounds
    decode-only; the aging bound still finishes the long prompt, and streams
    stay bit-identical to serial (deferral shifts rounds, not math)."""
    cfg, params = setup
    # exactly max_slots shorts ahead of the long prompt: its chunk rounds
    # run while every decode slot is busy, so the floor budget has no
    # chunk allowance until the shorts drain
    reqs = _mixed_requests(cfg, long_rids=(4,), n=5, max_new=10)
    off, _ = _run(params, cfg, reqs)
    on, srv = _run(params, cfg,
                   _mixed_requests(cfg, long_rids=(4,), n=5, max_new=10),
                   unified_batching=True, token_budget=4 + 32)
    assert on == off
    st = srv.unified_stats
    assert st["deferred_rounds"] > 0
    # the aging override bounds every deferral run
    assert st["deferred_rounds"] <= st["rounds"]


# ---------------------------------------------------------------------------
# Construction-time validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw,match", [
    (dict(chunk_tokens=None, unified_batching=True), "requires chunk_tokens"),
    (dict(token_budget=64), "unified_batching"),
    (dict(unified_batching=True, token_budget=8), "starve"),
    (dict(chunk_tokens="auto"), "tbt_target_ms"),
    (dict(chunk_tokens="auto", tbt_target_ms=-5.0), "positive"),
    (dict(chunk_tokens=24), "multiple"),
    (dict(chunk_tokens=32, paged=False), "paged"),
])
def test_config_rejects_unsatisfiable(kw, match):
    base = dict(max_slots=4, max_len=160, decode_block=4, paged=True,
                page_size=PAGE, chunk_tokens=32)
    base.update(kw)
    with pytest.raises(ValueError, match=match):
        EngineConfig(**base)


# ---------------------------------------------------------------------------
# chunk_tokens="auto": the measured-TBT tuner
# ---------------------------------------------------------------------------


def test_chunk_candidates_page_aligned():
    assert chunk_candidates(16, 128, (64,)) == [16, 32, 64]
    assert chunk_candidates(16, 32, ()) == [16, 32]
    for q in chunk_candidates(8, 200, (128, 64)):
        assert q % 8 == 0


@pytest.mark.slow
def test_tuner_respects_slo_bounds(setup):
    """A generous SLO picks the largest candidate; an impossible SLO falls
    back to one page.  Both are page-aligned by construction."""
    cfg, params = setup
    base = _config(max_len=64, chunk_tokens="auto", tbt_target_ms=1.0)
    report = {}
    loose = tune_chunk_tokens(params, cfg,
                              base.replace(tbt_target_ms=60_000.0),
                              report=report)
    assert loose == max(report["t_chunk_s"])  # largest candidate fits
    assert loose % PAGE == 0
    tight = tune_chunk_tokens(params, cfg,
                              base.replace(tbt_target_ms=1e-6))
    assert tight == PAGE


@pytest.mark.slow
def test_auto_resolves_through_from_config(setup):
    """from_config resolves "auto" to a concrete page-aligned quantum before
    building engines; the server then runs chunked prefill normally."""
    cfg, params = setup
    srv = DisaggregatedServer.from_config(
        params, cfg,
        _config(max_len=64, chunk_tokens="auto", tbt_target_ms=60_000.0),
    )
    q = srv.config.chunk_tokens
    assert isinstance(q, int) and q % PAGE == 0
    srv.submit(GenRequest(0, np.arange(40) % cfg.vocab_size, max_new_tokens=4))
    out = srv.run()
    assert len(out[0]) == 4
