"""View-free paged decode: the block-table path runs decode straight off the
page pools — no gathered slab view, no writeback.  Acceptance bar:

* the gather-free XLA fallback (``gather_pages`` as a one-hot contraction)
  is bit-identical to fancy-index gathering from the pool;
* model-level paged decode matches the RETIRED gather-view path
  (``kvcache.paged_gather_view``, kept as a test reference) bit for bit;
* end-to-end paged streams match slab streams across attention families
  {GQA, MLA, hybrid} x {greedy, sampled};
* the paged Pallas kernel's online-softmax partials accumulate correctly
  across many pages (interpret mode, runs on CPU in tier-1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_paged_pallas
from repro.models import model as M
from repro.models.attention import gather_pages
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    PrefillEngine,
    SamplingParams,
)
from repro.serving import kvcache

PAGE = 16


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def mla_setup():
    cfg = reduced(ARCHS["minicpm3-4b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def hybrid_setup():
    cfg = reduced(ARCHS["jamba-1.5-large-398b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=5, lo=5, hi=40):
    rng = np.random.default_rng(seed)
    return [
        GenRequest(i, rng.integers(0, cfg.vocab_size, size=int(rng.integers(lo, hi))),
                   max_new_tokens=max_new)
        for i in range(n)
    ]


def _server(params, cfg, *, paged, temperature=0.0, max_slots=3, max_len=128):
    sp = SamplingParams(temperature=temperature)
    return DisaggregatedServer(
        [PrefillEngine(params, cfg, sp)],
        [DecodeEngine(params, cfg, max_slots=max_slots, max_len=max_len,
                      sampling=sp, decode_block=8, paged=paged,
                      page_size=PAGE, seed=0)],
        seed=0,
    )


# ---------------------------------------------------------------------------
# gather_pages: the gather-free one-hot contraction IS the gather, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_pages_bitwise_equals_indexing(dtype):
    rng = np.random.default_rng(0)
    P, ps, KV, d, B, n_pg = 13, PAGE, 2, 16, 3, 5
    pool = jnp.asarray(rng.normal(size=(P, ps, KV, d)), dtype)
    bt = jnp.asarray(rng.integers(0, P, size=(B, n_pg)), jnp.int32)
    got = gather_pages(pool, bt)
    want = pool[bt].reshape(B, n_pg * ps, KV, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_pages_trailing_rank_generic():
    """MLA pools carry a different trailing rank ([P, ps, d]) — the one-hot
    contraction must be rank-agnostic."""
    rng = np.random.default_rng(1)
    P, ps, d, B, n_pg = 7, PAGE, 24, 2, 4
    pool = jnp.asarray(rng.normal(size=(P, ps, d)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, size=(B, n_pg)), jnp.int32)
    got = gather_pages(pool, bt)
    want = pool[bt].reshape(B, n_pg * ps, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Model-level: view-free decode == retired gather-view reference, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fixture", ["setup", "mla_setup", "hybrid_setup"])
def test_view_free_matches_retired_gather_view(fixture, request):
    """decode_step(block_tables=...) straight off the pools produces the
    exact logits of decoding against the materialized slab view the retired
    ``paged_gather_view`` path used to build."""
    cfg, params = request.getfixturevalue(fixture)
    max_slots, max_len = 3, 64
    n_pages = max_slots * max_len // PAGE
    st = kvcache.init_paged_decode_state(
        cfg, max_slots, max_len, PAGE, n_pages, jax.random.PRNGKey(1)
    )
    rng = np.random.default_rng(2)
    lens = [37, 18]
    for slot, n in enumerate(lens):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=n))[None]
        _, single, _ = M.prefill(params, toks, cfg)
        st = kvcache.paged_admit(st, single, jnp.int32(slot), jnp.int32(5),
                                 jnp.int32(n), cfg, page_size=PAGE)
    tok = jnp.array([3, 9, 0], jnp.int32)
    pos = jnp.array(lens + [0], jnp.int32)
    lg_free, _ = M.decode_step(params, tok, st.caches, pos, cfg,
                               block_tables=st.block_tables)
    view = kvcache.paged_gather_view(st.caches, st.block_tables, cfg)
    lg_view, _ = M.decode_step(params, tok, view, pos, cfg)
    np.testing.assert_array_equal(
        np.asarray(lg_free[:2]), np.asarray(lg_view[:2])
    )


# ---------------------------------------------------------------------------
# End-to-end streams: paged == slab across {GQA, MLA, hybrid} x {greedy, sampled}
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("fixture", ["setup", "mla_setup", "hybrid_setup"])
def test_view_free_streams_match_slab(fixture, temperature, request):
    cfg, params = request.getfixturevalue(fixture)
    outs = []
    for paged in (False, True):
        srv = _server(params, cfg, paged=paged, temperature=temperature)
        for r in _requests(cfg, 5, seed=3, max_new=4):
            srv.submit(r)
        outs.append(srv.run())
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Pallas kernel partials: online softmax across many pages (interpret mode)
# ---------------------------------------------------------------------------


def test_paged_pallas_partials_accumulate_across_pages():
    """Lengths spanning many pages force the kernel through repeated
    online-softmax rescale steps; the result must still match the reference
    (and be invariant to padding the table with extra trash entries)."""
    rng = np.random.default_rng(4)
    B, H, KV, d, P, n_pg = 2, 4, 2, 16, 17, 12
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, PAGE, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, PAGE, KV, d)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, size=(B, n_pg)), jnp.int32)
    lengths = jnp.array([n_pg * PAGE - 3, 5 * PAGE + 1], jnp.int32)
    out = decode_attention_paged_pallas(q, kp, vp, bt, lengths, interpret=True)
    want = ref.decode_attention_paged_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # widening the table with extra (ignored) columns must not perturb it
    bt_wide = jnp.concatenate([bt, jnp.zeros((B, 4), jnp.int32)], axis=1)
    out_w = decode_attention_paged_pallas(q, kp, vp, bt_wide, lengths,
                                          interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_w))
