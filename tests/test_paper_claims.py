"""Validation of the reproduction against the paper's own claims.

Each test cites the paper section/table/figure it checks.  Hardware-model
numbers (Table 3) are exact; simulator-level sensitivities (Figs 2/3) are
checked within bands (our LLMCompass-lite is calibrated, not identical).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import A100, DECODE_CHIP, H100, H100_PCAP, PREFILL_CHIP, Parallelism
from repro.core.hardware import (
    die_area_mm2,
    die_cost,
    dies_per_wafer,
    hw_cost,
    memory_cost,
    norm_hw_cost,
    norm_tdp,
    tdp_w,
)
from repro.core.opgraph import kv_bytes_per_token, phase_ops, weight_bytes
from repro.core.perfmodel import run_graph

BLOOM = get_config("bloom-176b")
PAR = Parallelism(tp=8)


# ---------------------------------------------------------------------------
# Table 3: derived chip specifications (exact)
# ---------------------------------------------------------------------------


def test_table3_tensor_flops():
    assert abs(H100.tensor_flops / 1e15 - 0.99) < 0.01
    assert abs(PREFILL_CHIP.tensor_flops / 1e15 - 1.92) < 0.01
    assert abs(DECODE_CHIP.tensor_flops / 1e15 - 0.54) < 0.01


def test_table3_vector_flops():
    assert abs(H100.vector_flops / 1e12 - 66.9) < 0.2
    assert abs(PREFILL_CHIP.vector_flops / 1e12 - 32.4) < 0.2
    assert abs(DECODE_CHIP.vector_flops / 1e12 - 18.2) < 0.2


def test_table3_memory_system():
    assert PREFILL_CHIP.mem_bw == 2048e9  # 512-bit x 32 Gb/s GDDR7
    assert PREFILL_CHIP.mem_capacity == 64e9
    assert DECODE_CHIP.mem_bw == 3352e9
    assert DECODE_CHIP.mem_capacity == 80e9


def test_table3_die_areas():
    """Area model calibrated: H100 814, Prefill 784, Decode 520 (within 1%)."""
    assert abs(die_area_mm2(H100) - 814) / 814 < 0.01
    assert abs(die_area_mm2(PREFILL_CHIP) - 784) / 784 < 0.01
    assert abs(die_area_mm2(DECODE_CHIP) - 520) / 520 < 0.01


def test_table3_die_costs():
    """$315 / $301 / $187 at $20k per 300mm wafer."""
    assert abs(die_cost(H100) - 315) < 4
    assert abs(die_cost(PREFILL_CHIP) - 301) < 4
    assert abs(die_cost(DECODE_CHIP) - 187) < 4


def test_table3_memory_costs():
    assert memory_cost(PREFILL_CHIP) == 192.0  # $3/GB x 64
    assert memory_cost(DECODE_CHIP) == 720.0  # $9/GB x 80
    assert memory_cost(H100) == 720.0


def test_table3_norm_hw_cost():
    assert abs(norm_hw_cost(PREFILL_CHIP) - 0.48) < 0.01
    assert abs(norm_hw_cost(DECODE_CHIP) - 0.88) < 0.01


def test_table3_tdp():
    """596 W / 507 W (H100 reported 700 W)."""
    assert abs(tdp_w(PREFILL_CHIP) - 596) < 8
    assert abs(tdp_w(DECODE_CHIP) - 507) < 8
    assert tdp_w(H100) == 700.0
    assert abs(norm_tdp(DECODE_CHIP) - 0.72) < 0.02  # paper: 28% lower TDP


def test_table9_hbm_cost_sensitivity():
    """Table 9: decode chip cost under $6/$9/$12 per GB HBM."""
    for price, chip_cost, h100_cost in [(6, 667, 795), (9, 907, 1035), (12, 1147, 1275)]:
        assert abs(hw_cost(DECODE_CHIP, price) - chip_cost) < 5
        assert abs(hw_cost(H100, price) - h100_cost) < 5


def test_dies_per_wafer_formula():
    # pi*r^2/A - pi*d/sqrt(2A): H100-sized die ~63 dies/300mm wafer
    assert 60 < dies_per_wafer(814) < 67


# ---------------------------------------------------------------------------
# §3 / Fig 2: prefill bandwidth sensitivity (bands)
# ---------------------------------------------------------------------------


def _prefill_latency(chip, bw=None):
    c = dataclasses.replace(chip, mem_bw_override_gbs=bw) if bw else chip
    return run_graph(c, phase_ops(BLOOM, phase="prefill", batch=2, seq=1024, par=PAR)).total


def test_fig2_prefill_bw_sensitivity():
    base = _prefill_latency(H100)
    r2500 = _prefill_latency(H100, 2500.0) / base - 1
    r2000 = _prefill_latency(H100, 2000.0) / base - 1
    r1500 = _prefill_latency(H100, 1500.0) / base - 1
    assert 0.04 < r2500 < 0.14, f"paper: +8%, got {r2500:.1%}"
    assert 0.12 < r2000 < 0.24, f"paper: +17%, got {r2000:.1%}"
    assert 0.25 < r1500 < 0.40, f"paper: +32%, got {r1500:.1%}"


def test_fig2_matmul_bw_sensitivity():
    """Matmul latency +16% from 4 TB/s -> 2 TB/s (paper §5.2.1)."""

    def matmul_total(bw):
        c = dataclasses.replace(H100, mem_bw_override_gbs=bw)
        r = run_graph(c, phase_ops(BLOOM, phase="prefill", batch=2, seq=1024, par=PAR))
        return sum(o.total for o in r.ops if o.kind == "matmul")

    ratio = matmul_total(2000.0) / matmul_total(4000.0) - 1
    assert 0.10 < ratio < 0.25, f"paper: +16%, got {ratio:.1%}"


# ---------------------------------------------------------------------------
# §3 / Fig 3: decode core-count sensitivity (bands)
# ---------------------------------------------------------------------------


def _decode_latency(cores):
    c = dataclasses.replace(H100, core_count=cores)
    return run_graph(c, phase_ops(BLOOM, phase="decode", batch=64, seq=1024, par=PAR)).total


def test_fig3_decode_core_sensitivity():
    base = _decode_latency(132)
    r108 = _decode_latency(108) / base - 1
    r66 = _decode_latency(66) / base - 1
    assert r108 < 0.08, f"paper: +2%, got {r108:.1%}"
    assert 0.12 < r66 < 0.32, f"paper: +22%, got {r66:.1%}"


# ---------------------------------------------------------------------------
# §5.4 / Fig 7: chip performance ratios (bands around paper averages)
# ---------------------------------------------------------------------------


def _grid_ratio(chip, phase, batches, seqs):
    ratios = []
    for b in batches:
        for s in seqs:
            need = weight_bytes(BLOOM) + kv_bytes_per_token(BLOOM) * b * s
            if need > min(8 * chip.mem_capacity, 8 * H100.mem_capacity) * 0.9:
                continue
            ops = phase_ops(BLOOM, phase=phase, batch=b, seq=s, par=PAR)
            ratios.append(run_graph(H100, ops).total / run_graph(chip, ops).total)
    return np.array(ratios)


PB, PS = [1, 2, 4, 8, 16], [64, 256, 1024, 2048, 4096, 8192, 12288, 16384]
DB, DS = [16, 32, 64, 128, 256], [256, 1024, 2048, 4096, 8192]


def test_fig7_prefill_chip():
    r = _grid_ratio(PREFILL_CHIP, "prefill", PB, PS)
    assert 0.95 < r.mean() < 1.20, f"paper avg 1.08, got {r.mean():.2f}"
    # paper: slower on very few batched tokens and very long prompts
    short = _grid_ratio(PREFILL_CHIP, "prefill", [1], [64])
    assert short.mean() < 1.0


def test_fig7_decode_chip():
    r = _grid_ratio(DECODE_CHIP, "decode", DB, DS)
    assert 0.85 < r.mean() <= 1.02, f"paper avg 0.97, got {r.mean():.2f}"
    cross_prefill = _grid_ratio(DECODE_CHIP, "prefill", PB, PS)
    assert 0.55 < cross_prefill.mean() < 0.85, f"paper avg 0.69, got {cross_prefill.mean():.2f}"
    cross_decode = _grid_ratio(PREFILL_CHIP, "decode", DB, DS)
    assert 0.60 < cross_decode.mean() < 0.90, f"paper avg 0.80, got {cross_decode.mean():.2f}"


# ---------------------------------------------------------------------------
# §B.1: memory capacity in tokens
# ---------------------------------------------------------------------------


def test_b1_kv_token_capacity():
    """8 H100s ~66K BLOOM tokens; 8 Prefill Chips ~35K (paper §B.1)."""
    from repro.core.cluster import ModelPerf

    h = ModelPerf(H100, BLOOM, PAR)
    p = ModelPerf(PREFILL_CHIP, BLOOM, PAR)
    assert 55_000 < h.max_kv_tokens < 70_000
    assert 30_000 < p.max_kv_tokens < 40_000


# ---------------------------------------------------------------------------
# Fig 5/6 DSE: the chosen chips sit on sensible frontier positions
# ---------------------------------------------------------------------------


def test_dse_systolic_tradeoffs():
    """Fig 5: bigger systolic arrays help prefill; Fig 6: decode doesn't care."""
    big = dataclasses.replace(H100, systolic_rows=32, systolic_cols=32,
                              reported_area_mm2=None, reported_tdp_w=None)
    small = dataclasses.replace(H100, systolic_rows=16, systolic_cols=16,
                                reported_area_mm2=None, reported_tdp_w=None)
    ops_p = phase_ops(BLOOM, phase="prefill", batch=2, seq=1024, par=PAR)
    ops_d = phase_ops(BLOOM, phase="decode", batch=64, seq=1024, par=PAR)
    # prefill: 2x systolic -> >25% faster
    assert run_graph(big, ops_p).total < 0.75 * run_graph(small, ops_p).total
    # decode: 4x systolic difference changes latency < 15%
    d_big = run_graph(big, ops_d).total
    d_small = run_graph(small, ops_d).total
    assert abs(d_big - d_small) / d_small < 0.15


def test_dse_vector_width_prefill():
    """Fig 5: halving vector width has minimal prefill impact (<8%)."""
    narrow = dataclasses.replace(H100, vector_width=16,
                                 reported_area_mm2=None, reported_tdp_w=None)
    ops_p = phase_ops(BLOOM, phase="prefill", batch=2, seq=1024, par=PAR)
    assert run_graph(narrow, ops_p).total < 1.08 * run_graph(H100, ops_p).total
