"""Benchmark rot guard: ``python -m benchmarks.serving_bench --smoke`` must
keep working (imports, engine APIs, slab-vs-paged-vs-shared-prefix stream
equivalence) without waiting for the full benchmark run — and the CI
regression gate's comparator logic is unit-tested here so the gate itself
cannot rot silently."""
import copy
import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks.check_regression import SAVING_FLOOR, compare  # noqa: E402


def test_serving_bench_smoke(tmp_path):
    env = dict(os.environ)
    src = str(REPO / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    json_path = tmp_path / "smoke.json"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.serving_bench", "--smoke",
         "--json", str(json_path)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=2400,
    )
    assert out.returncode == 0, f"smoke failed:\n{out.stdout}\n{out.stderr}"
    assert "SMOKE OK" in out.stdout
    assert "smoke_stream_mismatches,0" in out.stdout
    assert "smoke_shared_stream_mismatches,0" in out.stdout
    sm = json.loads(json_path.read_text())
    assert sm["stream_mismatches"] == 0
    assert sm["shared_prefix"]["stream_mismatches"] == 0
    assert sm["shared_prefix"]["kv_new_bytes_per_request"]["saving_frac"] >= SAVING_FLOOR


def _metrics(tps_ratio=0.9, spt_ratio=1.1, saving=0.45, mism=0, smism=0,
             fcfs_p99=5.0, kv_p99=3.0, sched_mism=0, preemptions=1,
             high_wait=1, preempt_mism=0, with_sched=True, with_rob=True,
             rob_seed=0, rob_mism=0, rob_audit=0, rob_recovery=4, rob_shed=2,
             with_rt=True, rt_holder=6, rt_recompute=0, rt_imbalance=1.0,
             rt_mism=0, rt_load=(4, 4),
             with_hbm=True, hbm_speedup=1.2,
             with_uni=True, uni_mism=0, uni_p99=0.002, uni_serial_p99=0.006,
             uni_stalls=2, uni_rows=2, uni_util=2.0 / 3.0,
             with_quant=True, q_conc_ratio=2.0, q_err=0.25, q_mism=0,
             q_spt_ratio=1.5, q_dd_mism=0, q_dd_audit=0, q_dd_saved=64,
             q_dd_base=110, q_int8_pages=37):
    out = {
        "tokens_per_s": {"slab": 1000.0, "paged": 1000.0 * tps_ratio,
                         "ratio": tps_ratio},
        "decode_s_per_token": {"slab": 1e-4, "paged": 1e-4 * spt_ratio,
                               "ratio": spt_ratio},
        "stream_mismatches": mism,
        "shared_prefix": {
            "stream_mismatches": smism,
            "kv_new_bytes_per_request": {"paged": 8000.0,
                                         "shared": 8000.0 * (1 - saving),
                                         "saving_frac": saving},
            "shared_pages_total": 10,
        },
    }
    if with_sched:
        out["scheduler"] = {
            "fcfs": {"queue_wait_rounds": {"p50": 4.0, "p99": fcfs_p99}},
            "kv_aware": {"queue_wait_rounds": {"p50": 1.5, "p99": kv_p99}},
            "stream_mismatches": sched_mism,
            "priority": {"swap": {"preemptions": preemptions,
                                  "high_wait_rounds": high_wait,
                                  "preempted_stream_mismatches": preempt_mism}},
        }
    if with_rob:
        out["robustness"] = {
            "seed": rob_seed,
            "stream_mismatches": rob_mism,
            "audit_discrepancies": rob_audit,
            "faults_injected": {"chunk_append": 1, "admit": 2,
                                "swap_in": 0, "swap_out": 0},
            "crash": {"round": 3, "affected": [0, 1, 2],
                      "recovery_rounds": rob_recovery},
            "shed": {"submitted": 10, "shed": rob_shed,
                     "served": 10 - rob_shed, "shed_after_rounds": 3},
        }
    if with_rt:
        out["router"] = {
            "replicas": 2,
            "skewed": {"matched_requests": 6,
                       "routed_to_holder": rt_holder,
                       "matched_pages": 12,
                       "matched_chunk_recompute": rt_recompute,
                       "per_replica_requests": list(rt_load),
                       "load_imbalance": rt_imbalance,
                       "load_imbalance_bound": 1.25},
            "unskewed": {"requests": 6, "stream_mismatches": rt_mism,
                         "per_replica_requests": [3, 3]},
        }
    if with_hbm:
        out["decode_tps_fixed_hbm"] = {
            "slab": 4000.0, "paged": 4000.0 * hbm_speedup,
            "speedup": hbm_speedup, "ratios": [hbm_speedup * 0.9, hbm_speedup],
        }
    if with_uni:
        out["unified_batching"] = {
            "trace": {"slots": 4, "token_budget": 36},
            "serial": {"tbt_p50_s": 0.004, "tbt_p99_s": uni_serial_p99,
                       "rounds": 6},
            "unified": {"tbt_p50_s": 0.0015, "tbt_p99_s": uni_p99,
                        "rounds": 8, "stall_rounds": uni_stalls,
                        "chunk_rows": uni_rows,
                        "budget_utilization": uni_util},
            "tbt_p99_ratio": uni_p99 / uni_serial_p99,
            "tbt_p99_improved": uni_p99 < uni_serial_p99,
            "stream_mismatches": uni_mism,
        }
    if with_quant:
        out["quantized_kv"] = {
            "page_size": 16,
            "hbm_budget_bytes": 100_000,
            "pages_at_budget": {"fp32": 18, "int8": q_int8_pages,
                                "capacity_ratio": q_int8_pages / 18},
            "fixed_hbm_concurrency": {"fp32": 7,
                                      "int8": int(7 * q_conc_ratio),
                                      "ratio": q_conc_ratio},
            "decode_s_per_token": {"fp32": 1e-4, "int8": 1e-4 * q_spt_ratio,
                                   "ratio": q_spt_ratio},
            "max_logit_err": q_err,
            "logit_drive_mismatches": 0,
            "stream_mismatches": q_mism,
            "dedup": {
                "requests": 4,
                "prefill_tokens": {"baseline": q_dd_base,
                                   "dedup": q_dd_base - q_dd_saved},
                "groups": 1 if q_dd_saved else 0,
                "saved_tokens": q_dd_saved,
                "stream_mismatches": q_dd_mism,
                "audit_discrepancies": q_dd_audit,
            },
        }
    return out


def test_regression_compare_passes_identical():
    ref = _metrics()
    assert all(ok for _, ok, _ in compare(copy.deepcopy(ref), ref))


def test_regression_compare_tolerates_machine_noise():
    # 20% slower ratio on a different machine: inside the 25% tolerance
    checks = compare(_metrics(tps_ratio=0.9 * 0.8, spt_ratio=1.1 * 1.2), _metrics())
    assert all(ok for _, ok, _ in checks)


def test_regression_compare_fails_on_mismatches():
    checks = {n: ok for n, ok, _ in compare(_metrics(smism=2), _metrics())}
    assert not checks["shared_stream_mismatches"]
    checks = {n: ok for n, ok, _ in compare(_metrics(mism=1), _metrics())}
    assert not checks["paged_stream_mismatches"]


def test_regression_compare_fails_on_throughput_regression():
    checks = {
        n: ok for n, ok, _ in compare(_metrics(tps_ratio=0.9 * 0.7), _metrics())
    }
    assert not checks["tokens_per_s_ratio"]
    checks = {
        n: ok for n, ok, _ in compare(_metrics(spt_ratio=1.1 * 1.3), _metrics())
    }
    assert not checks["decode_s_per_token_ratio"]


def test_regression_compare_scheduler_gates():
    # kv-aware must keep strictly beating fcfs on queue-wait p99
    checks = {
        n: ok for n, ok, _ in compare(_metrics(kv_p99=5.0), _metrics())
    }
    assert not checks["sched_kv_aware_p99_improves"]
    # round math is deterministic: any drift from the committed reference fails
    checks = {
        n: ok for n, ok, _ in compare(_metrics(kv_p99=2.0), _metrics())
    }
    assert not checks["sched_wait_rounds_committed"]
    assert checks["sched_kv_aware_p99_improves"]  # still an improvement
    # preempted streams must stay bit-exact; preemption count must not drift
    checks = {
        n: ok for n, ok, _ in compare(_metrics(preempt_mism=1), _metrics())
    }
    assert not checks["sched_preempted_streams_bitexact"]
    checks = {
        n: ok for n, ok, _ in compare(_metrics(preemptions=0, high_wait=4),
                                      _metrics())
    }
    assert not checks["sched_preemptions_committed"]
    checks = {
        n: ok for n, ok, _ in compare(_metrics(sched_mism=2), _metrics())
    }
    assert not checks["sched_stream_mismatches"]


def test_regression_compare_skips_scheduler_for_old_baselines():
    """A pre-scheduler committed reference must not fail the gate (the fresh
    run may carry the section; only the reference decides)."""
    checks = compare(_metrics(), _metrics(with_sched=False))
    assert all(ok for _, ok, _ in checks)
    assert not any(n.startswith("sched_") for n, _, _ in checks)


def test_regression_compare_robustness_gates():
    # chaos streams must stay bit-identical and the KV audit clean — always
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rob_mism=1), _metrics())
    }
    assert not checks["robust_stream_mismatches"]
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rob_audit=3), _metrics())
    }
    assert not checks["robust_audit_clean"]
    # same seed: recovery rounds / shed counts are exact
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rob_recovery=7), _metrics())
    }
    assert not checks["robust_schedule_committed"]
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rob_shed=5), _metrics())
    }
    assert not checks["robust_schedule_committed"]
    # different seed (local --seed experimentation): exact compare skipped,
    # but the unconditional gates still apply
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rob_seed=42, rob_recovery=7), _metrics())
    }
    assert checks["robust_schedule_committed"]
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rob_seed=42, rob_audit=1), _metrics())
    }
    assert not checks["robust_audit_clean"]


def test_regression_compare_skips_robustness_for_old_baselines():
    """A pre-robustness committed reference must not fail the gate."""
    checks = compare(_metrics(), _metrics(with_rob=False))
    assert all(ok for _, ok, _ in checks)
    assert not any(n.startswith("robust_") for n, _, _ in checks)


def test_regression_compare_router_gates():
    # every matched request must route to the page-holding replica
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rt_holder=4), _metrics())
    }
    assert not checks["router_routed_to_holder"]
    # matched pages must map, never recompute
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rt_recompute=2), _metrics())
    }
    assert not checks["router_matched_recompute"]
    # load imbalance gated against the committed bound
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rt_imbalance=1.5), _metrics())
    }
    assert not checks["router_load_imbalance"]
    # routed streams must stay bit-identical to single-replica FCFS
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rt_mism=1), _metrics())
    }
    assert not checks["router_stream_mismatches"]
    # replica assignments are deterministic: any drift fails
    checks = {
        n: ok for n, ok, _ in compare(_metrics(rt_load=(5, 3)), _metrics())
    }
    assert not checks["router_assignments_committed"]


def test_regression_compare_skips_router_for_old_baselines():
    """A pre-router committed reference must not fail the gate."""
    checks = compare(_metrics(), _metrics(with_rt=False))
    assert all(ok for _, ok, _ in checks)
    assert not any(n.startswith("router_") for n, _, _ in checks)


def test_regression_compare_fails_on_kv_accounting_drift():
    # deterministic accounting drifted from the committed value -> stale BENCH
    checks = {
        n: ok for n, ok, _ in compare(_metrics(saving=0.40), _metrics(saving=0.45))
    }
    assert not checks["kv_new_bytes_saving_committed"]
    # and the hard 30% acceptance floor
    checks = {
        n: ok for n, ok, _ in compare(_metrics(saving=0.2), _metrics(saving=0.2))
    }
    assert not checks["kv_new_bytes_saving_floor"]


def test_regression_compare_fixed_hbm_floor():
    # the 0.9 floor is HARD: a committed reference cannot lower it
    checks = {
        n: ok for n, ok, _ in compare(_metrics(hbm_speedup=0.7),
                                      _metrics(hbm_speedup=0.7))
    }
    assert not checks["fixed_hbm_speedup_floor"]
    checks = {
        n: ok for n, ok, _ in compare(_metrics(hbm_speedup=0.95), _metrics())
    }
    assert checks["fixed_hbm_speedup_floor"]


def test_regression_compare_skips_fixed_hbm_for_old_baselines():
    checks = compare(_metrics(), _metrics(with_hbm=False))
    assert all(ok for _, ok, _ in checks)
    assert not any(n.startswith("fixed_hbm") for n, _, _ in checks)


def test_regression_compare_unified_gates():
    # unified streams must stay bit-identical to serial chunked
    checks = {
        n: ok for n, ok, _ in compare(_metrics(uni_mism=1), _metrics())
    }
    assert not checks["unified_stream_mismatches"]
    # unified TBT p99 must beat the serial baseline strictly
    checks = {
        n: ok for n, ok, _ in compare(_metrics(uni_p99=0.007), _metrics())
    }
    assert not checks["unified_tbt_p99_improves"]
    # the round/budget shape is deterministic: drift fails
    checks = {
        n: ok for n, ok, _ in compare(_metrics(uni_stalls=0), _metrics())
    }
    assert not checks["unified_schedule_committed"]
    checks = {
        n: ok for n, ok, _ in compare(_metrics(uni_util=0.5), _metrics())
    }
    assert not checks["unified_schedule_committed"]


def test_regression_compare_skips_unified_for_old_baselines():
    checks = compare(_metrics(), _metrics(with_uni=False))
    assert all(ok for _, ok, _ in checks)
    assert not any(n.startswith("unified_") for n, _, _ in checks)


def test_regression_compare_quant_gates():
    # fixed-HBM concurrency floor is HARD: a committed reference cannot
    # lower it
    checks = {
        n: ok for n, ok, _ in compare(_metrics(q_conc_ratio=1.5),
                                      _metrics(q_conc_ratio=1.5))
    }
    assert not checks["quant_concurrency_floor"]
    # the per-step logit error gate is HARD too
    checks = {
        n: ok for n, ok, _ in compare(_metrics(q_err=0.9), _metrics())
    }
    assert not checks["quant_logit_error_gate"]
    # int8 greedy streams must match fp32 at reduced scale
    checks = {
        n: ok for n, ok, _ in compare(_metrics(q_mism=1), _metrics())
    }
    assert not checks["quant_stream_mismatches"]
    # decode walltime overhead compared as a ratio with tolerance
    checks = {
        n: ok for n, ok, _ in compare(_metrics(q_spt_ratio=1.5 * 1.3),
                                      _metrics())
    }
    assert not checks["quant_decode_s_per_token_ratio"]
    checks = {
        n: ok for n, ok, _ in compare(_metrics(q_spt_ratio=1.5 * 1.2),
                                      _metrics())
    }
    assert checks["quant_decode_s_per_token_ratio"]


def test_regression_compare_dedup_gates():
    # dedup streams must replay the dedup-free schedule bit for bit
    checks = {
        n: ok for n, ok, _ in compare(_metrics(q_dd_mism=1), _metrics())
    }
    assert not checks["dedup_stream_mismatches"]
    # refcounts conserved after the dedup drain
    checks = {
        n: ok for n, ok, _ in compare(_metrics(q_dd_audit=2), _metrics())
    }
    assert not checks["dedup_audit_clean"]
    # dispatched + saved must balance against the baseline, savings > 0
    checks = {
        n: ok for n, ok, _ in compare(_metrics(q_dd_saved=0), _metrics())
    }
    assert not checks["dedup_token_accounting"]
    # the deterministic capacity/accounting shape compares exactly
    checks = {
        n: ok for n, ok, _ in compare(_metrics(q_int8_pages=30), _metrics())
    }
    assert not checks["quant_capacity_committed"]
    assert checks["quant_concurrency_floor"]  # floors still independently ok


def test_regression_compare_skips_quant_for_old_baselines():
    """A pre-quantization committed reference must not fail the gate."""
    checks = compare(_metrics(), _metrics(with_quant=False))
    assert all(ok for _, ok, _ in checks)
    assert not any(
        n.startswith("quant_") or n.startswith("dedup_") for n, _, _ in checks
    )
