"""Benchmark rot guard: ``python -m benchmarks.serving_bench --smoke`` must
keep working (imports, engine APIs, slab-vs-paged stream equivalence) without
waiting for the full benchmark run."""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_serving_bench_smoke():
    env = dict(os.environ)
    src = str(REPO / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.serving_bench", "--smoke"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=540,
    )
    assert out.returncode == 0, f"smoke failed:\n{out.stdout}\n{out.stderr}"
    assert "SMOKE OK" in out.stdout
    assert "smoke_stream_mismatches,0" in out.stdout
