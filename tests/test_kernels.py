"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes (assignment requirement c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_pallas

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _mx(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Skv,H,KV,d,causal",
    [
        (1, 128, 128, 4, 4, 64, True),  # MHA square
        (2, 256, 256, 8, 2, 64, True),  # GQA 4:1
        (1, 64, 256, 4, 1, 32, True),  # MQA, q-chunk (Sq < Skv)
        (2, 128, 128, 4, 4, 64, False),  # encoder (non-causal)
        (1, 200, 200, 4, 2, 64, True),  # non-divisible seq (padding path)
        (1, 96, 96, 6, 3, 128, True),  # odd head counts, d=128
    ],
)
def test_flash_attention(dtype, B, Sq, Skv, H, KV, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(B * Sq + H), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, d), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, d), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, d), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    assert out.shape == want.shape
    assert _mx(out, want) < TOL[dtype], _mx(out, want)


@pytest.mark.parametrize("block", [32, 128, 512])
def test_flash_attention_block_sweep(block):
    """Block size must not change results (the paper's systolic-size knob)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=block, block_k=block, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert _mx(out, want) < 2e-5


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,L,H,KV,d,block_s",
    [
        (2, 512, 8, 2, 64, 128),
        (4, 300, 4, 4, 64, 128),  # non-divisible L
        (1, 2048, 16, 2, 128, 512),  # long ctx, split-K
        (3, 128, 6, 3, 32, 64),
    ],
)
def test_decode_attention(dtype, B, L, H, KV, d, block_s):
    ks = jax.random.split(jax.random.PRNGKey(B + L), 4)
    q = jax.random.normal(ks[0], (B, H, d), dtype)
    kc = jax.random.normal(ks[1], (B, L, KV, d), dtype)
    vc = jax.random.normal(ks[2], (B, L, KV, d), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, L + 1)
    out = decode_attention_pallas(q, kc, vc, lengths, block_s=block_s, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    assert _mx(out, want) < TOL[dtype]


def test_decode_attention_masks_beyond_length():
    """Garbage beyond `length` must not leak into the output."""
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    B, L, H, KV, d = 2, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, H, d), jnp.float32)
    kc = jax.random.normal(ks[1], (B, L, KV, d), jnp.float32)
    vc = jax.random.normal(ks[2], (B, L, KV, d), jnp.float32)
    lengths = jnp.array([100, 177])
    out1 = decode_attention_pallas(q, kc, vc, lengths, block_s=64, interpret=True)
    kc2 = kc.at[0, 100:].set(1e4)
    vc2 = vc.at[1, 177:].set(-1e4)
    out2 = decode_attention_pallas(q, kc2, vc2, lengths, block_s=64, interpret=True)
    assert _mx(out1, out2) == 0.0


# ---------------------------------------------------------------------------
# SSD (Mamba-2) scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,L,h,p,g,n,chunk",
    [
        (2, 256, 4, 32, 2, 16, 64),
        (1, 100, 2, 16, 1, 8, 32),  # non-divisible L
        (2, 128, 8, 64, 2, 32, 128),  # single chunk
    ],
)
def test_ssd(dtype, b, L, h, p, g, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(L + h), 5)
    x = (jax.random.normal(ks[0], (b, L, h, p), jnp.float32) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    B = (jax.random.normal(ks[3], (b, L, g, n), jnp.float32) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (b, L, g, n), jnp.float32) * 0.3).astype(dtype)
    y, state = ssd_pallas(x, dt, A, B, C, chunk=chunk, interpret=True)
    y_ref, state_ref = ref.ssd_sequential_ref(x, dt, A, B, C)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    assert _mx(y, y_ref) < tol
    assert _mx(state, state_ref) < tol


def test_ssd_initial_state_chaining():
    """Running [0:L1] then [L1:L] with carried state == running [0:L]."""
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    b, L, h, p, g, n = 1, 128, 2, 16, 1, 8
    x = jax.random.normal(ks[0], (b, L, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    B = jax.random.normal(ks[3], (b, L, g, n), jnp.float32) * 0.3
    C = jax.random.normal(ks[4], (b, L, g, n), jnp.float32) * 0.3
    y_full, s_full = ssd_pallas(x, dt, A, B, C, chunk=32, interpret=True)
    L1 = 64
    y1, s1 = ssd_pallas(x[:, :L1], dt[:, :L1], A, B[:, :L1], C[:, :L1], chunk=32, interpret=True)
    y2, s2 = ssd_pallas(
        x[:, L1:], dt[:, L1:], A, B[:, L1:], C[:, L1:], chunk=32,
        initial_state=s1, interpret=True,
    )
    assert _mx(jnp.concatenate([y1, y2], 1), y_full) < 1e-4
    assert _mx(s2, s_full) < 1e-4


# ---------------------------------------------------------------------------
# Bucketed-prefill length masking (serving fast path)
# ---------------------------------------------------------------------------


def test_flash_attention_lengths_masks_padding():
    """Per-request `lengths` == running each request at its true length."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    B, S, H, KV, d = 3, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, d), jnp.float32)
    lengths = jnp.array([37, 128, 65])
    out = flash_attention_pallas(
        q, k, v, lengths, causal=True, block_q=32, block_k=32, interpret=True
    )
    for b in range(B):
        n = int(lengths[b])
        want = ref.flash_attention_ref(
            q[b : b + 1, :n], k[b : b + 1, :n], v[b : b + 1, :n], causal=True
        )
        assert _mx(out[b : b + 1, :n], want) < 2e-5


def test_flash_attention_lengths_ignore_padding_garbage():
    """Keys/values beyond lengths[b] must not leak into valid rows."""
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    B, S, H, KV, d = 2, 96, 4, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, d), jnp.float32)
    lengths = jnp.array([50, 96])
    out1 = flash_attention_pallas(q, k, v, lengths, block_q=32, block_k=32, interpret=True)
    k2 = k.at[0, 50:].set(1e4)
    v2 = v.at[0, 50:].set(-1e4)
    out2 = flash_attention_pallas(q, k2, v2, lengths, block_q=32, block_k=32, interpret=True)
    assert _mx(out1[:, :50], out2[:, :50]) == 0.0


def test_decode_attention_max_length_bound():
    """Capping the split grid at the max admitted length changes nothing."""
    ks = jax.random.split(jax.random.PRNGKey(13), 4)
    B, L, H, KV, d = 2, 1024, 4, 2, 64
    q = jax.random.normal(ks[0], (B, H, d), jnp.float32)
    kc = jax.random.normal(ks[1], (B, L, KV, d), jnp.float32)
    vc = jax.random.normal(ks[2], (B, L, KV, d), jnp.float32)
    lengths = jnp.array([100, 177])
    full = decode_attention_pallas(q, kc, vc, lengths, block_s=64, interpret=True)
    bounded = decode_attention_pallas(
        q, kc, vc, lengths, block_s=64, max_length=192, interpret=True
    )
    assert _mx(full, bounded) == 0.0
