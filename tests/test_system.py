"""End-to-end behaviour tests for the paper's system.

These exercise the same code paths as the production launchers, at reduced
scale on CPU: distributed step building (jit + shardings on a real mesh),
disaggregated serving through the public API, and the provisioning story
(analytical models -> cluster design) end to end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import ARCHS, SHAPES, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.steps import build_step, input_specs
from repro.models import model as M


def _tiny_mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_build_step_executes_on_cpu(kind):
    """The dry-run's exact step builders also *run* (reduced config, 1 device)."""
    cfg = reduced(ARCHS["granite-8b"])
    shape = ShapeConfig("tiny", seq_len=16, global_batch=2, kind=kind)
    mesh = _tiny_mesh()
    with mesh:
        step, args = build_step(cfg, shape, mesh)
        concrete = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            args,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        out = step(*concrete)
        jax.block_until_ready(out)


def test_input_specs_cover_assigned_matrix():
    """input_specs returns well-formed specs for every applicable cell."""
    from repro.configs import ASSIGNED_ARCHS, shape_applicable

    n = 0
    for cfg in ASSIGNED_ARCHS.values():
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                with pytest.raises(ValueError):
                    input_specs(cfg, shape)
                continue
            specs = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
            n += 1
    assert n == 31


def test_mesh_functions_do_not_require_512_devices():
    """Importing mesh module works on 1 CPU; building the big mesh fails loudly."""
    from repro.launch import mesh as mesh_mod

    with pytest.raises(Exception):
        mesh_mod.make_production_mesh()  # needs 256 devices, we have 1


@pytest.mark.slow
def test_train_then_serve_roundtrip():
    """Train a reduced model briefly, then serve it disaggregated."""
    from repro.serving import DecodeEngine, DisaggregatedServer, GenRequest, PrefillEngine
    from repro.training import DataConfig, Trainer, TrainerConfig

    cfg = reduced(ARCHS["qwen1.5-4b"])
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2, seed=0)
    tr = Trainer(cfg, dcfg, TrainerConfig(total_steps=5, ckpt_every=100, warmup=1), seed=0)
    tr.run()
    srv = DisaggregatedServer(
        [PrefillEngine(tr.params, cfg)],
        [DecodeEngine(tr.params, cfg, max_slots=2, max_len=64)],
    )
    rng = np.random.default_rng(0)
    for i in range(3):
        srv.submit(GenRequest(i, rng.integers(0, cfg.vocab_size, size=10), max_new_tokens=4))
    out = srv.run()
    assert len(out) == 3 and all(len(v) == 4 for v in out.values())


def test_provisioning_story_end_to_end():
    """Analytical chip models -> cluster design, via the public API."""
    from repro.core import DECODE_CHIP, H100, PREFILL_CHIP, Parallelism
    from repro.core.cluster import SLOS, ModelPerf
    from repro.core.provision import Design, PoolSpec, evaluate
    from repro.core.trace import CONVERSATION, synthesize

    bloom = get_config("bloom-176b")
    par = Parallelism(tp=8)
    h = ModelPerf(H100, bloom, par)
    p = ModelPerf(PREFILL_CHIP, bloom, par)
    d = ModelPerf(DECODE_CHIP, bloom, par)
    design = Design(
        "spad", "disagg",
        prefill=[PoolSpec("PrefillChip", p, 2)],
        decode=[PoolSpec("DecodeChip", d, 3)],
    )
    reqs = synthesize(CONVERSATION, rate_rps=8, duration_s=15, seed=0)
    res = evaluate(design, reqs, h, 15)
    assert res.n_completed == res.n_requests
    assert design.norm_cost < 5  # 2*0.48 + 3*0.88 = 3.6 H100-equivalents
