"""Architecture configs: parameter counts vs published sizes, applicability."""
import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, SHAPES, get_config, reduced, shape_applicable

# (arch, published total B, published active B, tolerance)
PUBLISHED = [
    ("qwen3-moe-235b-a22b", 235, 22, 0.10),
    ("arctic-480b", 480, 17, 0.15),
    ("jamba-1.5-large-398b", 398, 94, 0.10),
    ("granite-8b", 8, 8, 0.10),
    ("nemotron-4-15b", 15, 15, 0.10),
    ("qwen1.5-4b", 4, 4, 0.15),
    ("minicpm3-4b", 4, 4, 0.15),
    ("mamba2-370m", 0.37, 0.37, 0.15),
    ("internvl2-2b", 2, 2, 0.15),
    ("hubert-xlarge", 0.96, 0.96, 0.15),
    ("bloom-176b", 176, 176, 0.05),
    ("llama3-70b", 70, 70, 0.05),
    ("deepseek-v2-236b", 236, 21, 0.10),
]


@pytest.mark.parametrize("name,total_b,active_b,tol", PUBLISHED)
def test_param_counts_match_published(name, total_b, active_b, tol):
    total, active = ARCHS[name].param_count()
    assert abs(total / 1e9 - total_b) / total_b < tol, f"{name}: {total/1e9:.1f}B"
    assert abs(active / 1e9 - active_b) / active_b < tol + 0.05, f"{name}: {active/1e9:.1f}B"


def test_assigned_matrix_is_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(SHAPES) == 4
    # 40 cells; count applicable ones
    applicable = sum(
        shape_applicable(cfg, s)[0] for cfg in ASSIGNED_ARCHS.values() for s in SHAPES.values()
    )
    # hubert: -2 (both decode shapes); long_500k inapplicable for the 7
    # remaining full-attention archs (jamba + mamba2 run it) -> 40 - 2 - 7
    assert applicable == 31


def test_applicability_reasons():
    hubert = get_config("hubert-xlarge")
    ok, why = shape_applicable(hubert, SHAPES["decode_32k"])
    assert not ok and "encoder" in why
    granite = get_config("granite-8b")
    ok, why = shape_applicable(granite, SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    for name in ("jamba-1.5-large-398b", "mamba2-370m"):
        ok, _ = shape_applicable(get_config(name), SHAPES["long_500k"])
        assert ok, name


def test_block_patterns_divide_layers():
    for name, cfg in ARCHS.items():
        assert cfg.n_layers % len(cfg.block_pattern) == 0, name
        _ = cfg.n_repeats


def test_jamba_interleave():
    cfg = get_config("jamba-1.5-large-398b")
    mixers = [m for m, _ in cfg.block_pattern]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7  # 1:7
    ffns = [f for _, f in cfg.block_pattern]
    assert ffns.count("moe") == 4  # MoE every other layer


def test_reduced_configs_are_small():
    for name, cfg in ARCHS.items():
        r = reduced(cfg)
        total, _ = r.param_count()
        assert total < 5e6, f"{name} reduced too big: {total/1e6:.1f}M"
        assert r.n_layers <= len(cfg.block_pattern) * 2


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("gpt-5")
