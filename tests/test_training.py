"""Training substrate: optimizer math, checkpoints, FT drill, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.training import (
    DataConfig,
    Trainer,
    TrainerConfig,
    adamw_for,
    cosine_schedule,
    global_norm,
    make_batch,
)
from repro.training import checkpoint as ckpt
from repro.training.optimizer import AdamW, clip_by_global_norm, constant_schedule


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=constant_schedule(0.1), weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}  # norm 10
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 10.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110, min_frac=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(lr(jnp.int32(110))) - 0.1) < 1e-6
    assert float(lr(jnp.int32(60))) > float(lr(jnp.int32(100)))


def test_weight_decay_only_matrices():
    opt = AdamW(lr=constant_schedule(0.0), weight_decay=1.0)  # lr 0: no movement
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = opt.init(params)
    grads = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    new, _, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0)  # lr=0 -> unchanged
    np.testing.assert_allclose(np.asarray(new["b"]), 1.0)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_by_step():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    a1, l1 = make_batch(cfg, 3)
    a2, l2 = make_batch(cfg, 3)
    b, _ = make_batch(cfg, 4)
    assert np.array_equal(a1, a2) and np.array_equal(l1, l2)
    assert not np.array_equal(a1, b)
    assert a1.max() < 100 and l1.max() < 100


def test_data_frontend_mode():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2, frontend_dim=32)
    x, labels = make_batch(cfg, 0)
    assert x.shape == (2, 8, 32) and x.dtype == np.float32
    assert labels.shape == (2, 8)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_bf16():
    tree = {
        "a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        "b": {"c": jnp.float32(3.5), "d": jnp.arange(4, dtype=jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, tree, step=5)
        got, step = ckpt.restore(d, tree)
        assert step == 5
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(got), strict=True):
            assert x.dtype == y.dtype
            assert np.array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_checkpoint_retention_and_latest():
    tree = {"w": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        for s in [1, 2, 3, 4, 5]:
            ckpt.save(d, tree, step=s, keep=2)
        assert ckpt.latest_step(d) == 5
        dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(dirs) == 2


def test_restore_or_none_cold_start():
    with tempfile.TemporaryDirectory() as d:
        assert ckpt.restore_or_none(d, {"w": jnp.zeros((2,))}) is None


# ---------------------------------------------------------------------------
# Fault-tolerance drill: kill mid-run, resume, bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_ft_drill_resume_bit_exact():
    cfg = reduced(ARCHS["granite-8b"])
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2, seed=1)
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(total_steps=10, ckpt_every=4, ckpt_dir=d, warmup=2)
        tr = Trainer(cfg, dcfg, tcfg, seed=0)
        with pytest.raises(RuntimeError, match="injected failure"):
            tr.run(stop_after=6)
        tr2 = Trainer(cfg, dcfg, tcfg, seed=0)
        assert tr2.resume() and tr2.step == 4
        last_resumed = tr2.run()
        tr3 = Trainer(cfg, dcfg, TrainerConfig(total_steps=10, ckpt_every=100, warmup=2), seed=0)
        last_clean = tr3.run()
        assert abs(last_resumed["loss"] - last_clean["loss"]) < 1e-5


@pytest.mark.slow
def test_loss_decreases():
    cfg = reduced(ARCHS["qwen1.5-4b"])
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=3)
    tr = Trainer(cfg, dcfg, TrainerConfig(total_steps=30, ckpt_every=1000, warmup=5,
                                          base_lr=1e-3), seed=0)
    tr.run()
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first - 0.1, (first, last)


def test_straggler_detection():
    import time as _time

    cfg = reduced(ARCHS["mamba2-370m"])
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2, seed=0)
    tr = Trainer(cfg, dcfg, TrainerConfig(total_steps=12, ckpt_every=1000,
                                          straggler_factor=2.5), seed=0)
    inner = tr._step_fn
    calls = {"n": 0}

    def slow_step(*a):
        calls["n"] += 1
        out = inner(*a)
        jax.block_until_ready(out[0])
        if calls["n"] == 10:
            _time.sleep(1.0)  # injected straggler
        return out

    tr._step_fn = slow_step
    tr.run()
    assert 9 in tr.straggler_steps or 10 in tr.straggler_steps
